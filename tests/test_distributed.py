"""Multi-device execution tests.

These run in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count=8
(jax locks device count at first init, so the main pytest process must stay
single-device for the smoke tests).  Each subprocess script asserts internally
and exits non-zero on failure.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def run_distributed(body: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    script = textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_sample_sort_all_pivots_correct_and_random_worst():
    out = run_distributed("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.sort import distributed_sort, PIVOT_STRATEGIES
        mesh = jax.make_mesh((8,), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(3), (4096,))
        ref = np.sort(np.asarray(x))
        imb = {}
        for pivot in PIVOT_STRATEGIES:
            out, rep = distributed_sort(x, mesh, "data", pivot=pivot, force_parallel=True)
            np.testing.assert_array_equal(np.asarray(out), ref), pivot
            imb[pivot] = rep.imbalance
            assert rep.strategy == "sample_sort"
        print("IMBALANCE", imb)
        # paper Table 3: single-candidate pivots are worse than regular sampling
        assert imb["sampled"] <= min(imb["left"], imb["right"], imb["random"]) + 1e-6
        # left/right pivots are catastrophic (first shard keeps almost nothing/all)
        assert imb["left"] > 1.5 or imb["right"] > 1.5
    """)
    assert "IMBALANCE" in out


def test_sample_sort_nonuniform_input():
    run_distributed("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.sort import distributed_sort
        mesh = jax.make_mesh((8,), ("data",))
        # skewed data: exponential + duplicates + non-multiple length
        key = jax.random.PRNGKey(0)
        x = jnp.concatenate([jnp.exp(jax.random.normal(key, (3000,))),
                             jnp.zeros(137), jnp.ones(500)*3.3])
        out, rep = distributed_sort(x, mesh, "data", pivot="sampled", force_parallel=True)
        np.testing.assert_allclose(np.asarray(out), np.sort(np.asarray(x)), rtol=0, atol=0)
    """)


def test_adaptive_matmul_parallel_strategies_match_serial():
    run_distributed("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.dispatch import adaptive_matmul
        mesh = jax.make_mesh((8,), ("data",))
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        a = jax.random.normal(k1, (104, 72))   # non-multiples: exercises padding
        b = jax.random.normal(k2, (72, 88))
        ref = np.asarray(a @ b)
        for strat in ("shard_m", "shard_n", "shard_k"):
            out = adaptive_matmul(a, b, mesh, "data", force_strategy=strat)
            np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4, rtol=1e-4), strat
        # the real decision on 8 chips for a small matmul must be serial
        out, rep = adaptive_matmul(a, b, mesh, "data", return_report=True)
        assert rep.chosen.strategy == "serial"
        np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4, rtol=1e-4)
    """)


def test_moe_ep_matches_dense_oracle():
    run_distributed("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import ffn as ffn_lib
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        d, f, e, topk = 32, 64, 8, 2
        params = ffn_lib.moe_init(jax.random.PRNGKey(1), d, f, e, "swiglu")
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, d))
        ref, aux_ref = ffn_lib.moe_dense(params, x, top_k=topk, activation="swiglu")
        y, aux = ffn_lib.moe_ep(params, x, top_k=topk, activation="swiglu",
                                mesh=mesh, data_axes=("data",),
                                capacity_factor=8.0)  # no drops
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)
    """)


def test_pjit_train_loss_matches_single_device():
    """Whole-model pjit on a (pod,data,model) mesh == unsharded execution."""
    run_distributed("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import build_model
        from repro.distributed.sharding import ShardingCtx, param_shardings, batch_sharding

        cfg = get_config("moonshot-v1-16b-a3b").reduced()  # MoE: hardest case
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, S = 8, 32
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)}
        ref, _ = jax.jit(model.loss)(params, batch)

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        ctx = ShardingCtx(mesh=mesh, data_axes=("pod", "data"), moe_capacity_factor=8.0)
        pshard = param_shardings(jax.eval_shape(lambda: params), mesh,
                                 data_axes=("pod", "data"))
        params_s = jax.device_put(params, pshard)
        batch_s = jax.device_put(batch, batch_sharding(jax.eval_shape(lambda: batch), mesh,
                                                       data_axes=("pod", "data")))
        loss, _ = jax.jit(lambda p, b: model.loss(p, b, ctx))(params_s, batch_s)
        print("ref", float(ref), "sharded", float(loss))
        np.testing.assert_allclose(float(loss), float(ref), rtol=2e-3)
    """)
