"""Continuous-batching serving: correctness anchors.

* static-vs-continuous token equivalence (the engine rewrite's invariant),
  across model families (chunked prefill + the chunk-1 replay fallback),
  including slot queueing/reuse (n_slots < n_requests)
* slot reuse after eviction matches a fresh engine (decode-state reset)
* EOS early-stop + deterministic padding in both engines
* scheduler decisions land as site=serve overhead-ledger rows
* explicit max_len validation (no silent slack)
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.costs.engine import CostEngine
from repro.models import build_model
from repro.models.model import mrope_positions
from repro.runtime import Runtime, set_default_runtime
from repro.serving import (
    ContinuousServeEngine,
    Request,
    ServeEngine,
    supports_chunked_prefill,
)

PROMPT_LEN = 7
MAX_NEW = 9
MAX_LEN = PROMPT_LEN + MAX_NEW


@pytest.fixture(autouse=True)
def _fresh_runtime():
    # each test gets its own session (isolated engine + ledger); engines
    # that are not passed one explicitly fall back to this default Runtime
    set_default_runtime(Runtime())
    yield
    set_default_runtime(None)


def _build(arch, key=0, **overrides):
    cfg = get_config(arch).reduced()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(key))
    return cfg, model, params


def _prompts(cfg, b, p=PROMPT_LEN, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, cfg.vocab_size, (b, p)).astype(np.int32)


def _run_continuous(model, params, prompts, max_new, *, n_slots, **kw):
    engine = ContinuousServeEngine(
        model, params, n_slots=n_slots, max_len=MAX_LEN, eos_id=0, **kw)
    reqs = [Request(f"r{i}", prompts[i], max_new) for i in range(len(prompts))]
    report = engine.run(reqs, now_fn=lambda: 0.0)
    return np.stack([report.output(f"r{i}", max_new)
                     for i in range(len(prompts))]), report


# ---------------------------------------------------------------------------
# Token-for-token equivalence with the static baseline
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", [
    "tinyllama-1.1b",       # dense attn -> chunked prefill
    "qwen2-vl-72b",         # mrope positions through the shared helper
    "rwkv6-3b",             # recurrent -> chunk-1 replay fallback
    "recurrentgemma-2b",    # hybrid local ring buffer -> replay fallback
])
def test_continuous_matches_static(arch):
    cfg, model, params = _build(arch)
    prompts = _prompts(cfg, 3)
    static = ServeEngine(model, params, max_len=MAX_LEN, eos_id=0)
    expected = static.generate(prompts, max_new_tokens=MAX_NEW)
    # n_slots < n_requests: forces queueing and slot reuse after eviction
    got, _ = _run_continuous(model, params, prompts, MAX_NEW, n_slots=2)
    np.testing.assert_array_equal(got, expected)


def test_continuous_matches_static_scan_layout():
    """Uniform stacks with >= 4 layers store decode state scanned (slot axis
    1); slot insert/reset must hit the right axis there too."""
    cfg, model, params = _build("tinyllama-1.1b", n_layers=4)
    prompts = _prompts(cfg, 3)
    static = ServeEngine(model, params, max_len=MAX_LEN, eos_id=0)
    expected = static.generate(prompts, max_new_tokens=MAX_NEW)
    got, _ = _run_continuous(model, params, prompts, MAX_NEW, n_slots=2)
    np.testing.assert_array_equal(got, expected)


def test_chunked_prefill_matches_replay():
    """Chunked prefill (multi-token chunks through decode_step) must emit
    the same tokens as the per-token replay it replaces."""
    cfg, model, params = _build("tinyllama-1.1b")
    prompts = _prompts(cfg, 2)
    replay, _ = _run_continuous(model, params, prompts, MAX_NEW,
                                n_slots=2, prefill_chunk=1)
    chunked, _ = _run_continuous(model, params, prompts, MAX_NEW,
                                 n_slots=2, prefill_chunk=4)
    np.testing.assert_array_equal(chunked, replay)


def test_ragged_prompts_match_single_request_runs():
    """Per-slot cache positions: requests with different prompt lengths
    decode concurrently yet match isolated single-request runs."""
    cfg, model, params = _build("tinyllama-1.1b")
    rng = np.random.default_rng(3)
    lens = [4, 7, 10]
    prompts = [rng.integers(1, cfg.vocab_size, (p,)).astype(np.int32)
               for p in lens]
    max_len = max(lens) + MAX_NEW
    engine = ContinuousServeEngine(model, params, n_slots=3,
                                   max_len=max_len, eos_id=0)
    report = engine.run(
        [Request(f"r{i}", prompts[i], MAX_NEW) for i in range(3)],
        now_fn=lambda: 0.0)
    static = ServeEngine(model, params, max_len=max_len, eos_id=0)
    for i in range(3):
        expected = static.generate(prompts[i][None], max_new_tokens=MAX_NEW)[0]
        np.testing.assert_array_equal(report.output(f"r{i}", MAX_NEW), expected)


def test_staggered_arrivals_under_pinned_clock():
    """A frozen test clock with nonzero arrivals must event-skip to the next
    arrival (not sleep forever), and stay token-identical to the baseline."""
    cfg, model, params = _build("tinyllama-1.1b")
    prompts = _prompts(cfg, 3)
    static = ServeEngine(model, params, max_len=MAX_LEN, eos_id=0)
    expected = static.generate(prompts, max_new_tokens=MAX_NEW)
    engine = ContinuousServeEngine(model, params, n_slots=1,
                                   max_len=MAX_LEN, eos_id=0)
    report = engine.run(
        [Request(f"r{i}", prompts[i], MAX_NEW, arrival_s=0.1 * i)
         for i in range(3)],
        now_fn=lambda: 0.0)
    got = np.stack([report.output(f"r{i}", MAX_NEW) for i in range(3)])
    np.testing.assert_array_equal(got, expected)
    assert all(r.ttft_s is not None and r.ttft_s >= 0 for r in report.requests)


# ---------------------------------------------------------------------------
# Slot reuse / reset correctness
# ---------------------------------------------------------------------------


def test_slot_reuse_after_eviction_matches_fresh_engine():
    """A request served on a recycled slot must see no trace of the evicted
    one: its output equals the same request on a fresh engine."""
    cfg, model, params = _build("tinyllama-1.1b")
    prompts = _prompts(cfg, 2, seed=7)
    engine = ContinuousServeEngine(model, params, n_slots=1,
                                   max_len=MAX_LEN, eos_id=0)
    report = engine.run(
        [Request("first", prompts[0], MAX_NEW),
         Request("reused", prompts[1], MAX_NEW)],
        now_fn=lambda: 0.0)
    fresh = ContinuousServeEngine(model, params, n_slots=1,
                                  max_len=MAX_LEN, eos_id=0)
    fresh_report = fresh.run([Request("alone", prompts[1], MAX_NEW)],
                             now_fn=lambda: 0.0)
    np.testing.assert_array_equal(report.output("reused", MAX_NEW),
                                  fresh_report.output("alone", MAX_NEW))


# ---------------------------------------------------------------------------
# EOS handling
# ---------------------------------------------------------------------------


def _pick_eos(model, params, prompts, step=3):
    """Choose as EOS the token the first row actually emits at ``step``
    (so EOS genuinely triggers mid-generation)."""
    probe = ServeEngine(model, params, max_len=MAX_LEN, eos_id=-1)
    base = probe.generate(prompts, max_new_tokens=MAX_NEW)
    return base, int(base[0, step])


def test_static_eos_early_stop_and_padding():
    cfg, model, params = _build("tinyllama-1.1b")
    prompts = _prompts(cfg, 2)
    base, eos = _pick_eos(model, params, prompts)
    engine = ServeEngine(model, params, max_len=MAX_LEN, eos_id=eos, pad_id=0)
    out = engine.generate(prompts, max_new_tokens=MAX_NEW)
    row = out[0]
    k = int(np.flatnonzero(row == eos)[0])
    # tokens before EOS match the unconstrained run, EOS kept, rest padded
    np.testing.assert_array_equal(row[: k + 1], base[0, : k + 1])
    assert np.all(row[k + 1 :] == 0)
    # rows that never emit EOS are unchanged
    if eos not in base[1]:
        np.testing.assert_array_equal(out[1], base[1])


def test_continuous_eos_matches_static():
    cfg, model, params = _build("tinyllama-1.1b")
    prompts = _prompts(cfg, 2)
    _, eos = _pick_eos(model, params, prompts)
    static = ServeEngine(model, params, max_len=MAX_LEN, eos_id=eos, pad_id=0)
    expected = static.generate(prompts, max_new_tokens=MAX_NEW)
    engine = ContinuousServeEngine(model, params, n_slots=2, max_len=MAX_LEN,
                                   eos_id=eos, pad_id=0)
    report = engine.run([Request(f"r{i}", prompts[i], MAX_NEW)
                         for i in range(2)], now_fn=lambda: 0.0)
    got = np.stack([report.output(f"r{i}", MAX_NEW) for i in range(2)])
    np.testing.assert_array_equal(got, expected)
    # the finished request must have stopped early (freed its slot)
    finished = next(r for r in report.requests if eos in r.tokens)
    assert len(finished.tokens) < MAX_NEW or finished.tokens[-1] == eos


# ---------------------------------------------------------------------------
# Macro-step decode (the host-sync-free hot path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", [
    "tinyllama-1.1b",       # dense attn -> chunked prefill
    "qwen2-vl-72b",         # mrope positions computed on device
    "rwkv6-3b",             # recurrent -> chunk-1 replay fallback
    "recurrentgemma-2b",    # hybrid local ring buffer -> replay fallback
])
def test_macro_step_eos_turnover_ragged_budgets_match_static(arch):
    """Pinned K=4 macro-steps with a mid-macro EOS, slot turnover
    (n_slots < n_requests) and ragged per-request ``max_new_tokens`` stay
    token-identical to the static baseline."""
    cfg, model, params = _build(arch)
    prompts = _prompts(cfg, 3)
    base, eos = _pick_eos(model, params, prompts)  # EOS fires at step 3 of r0
    static = ServeEngine(model, params, max_len=MAX_LEN, eos_id=eos, pad_id=0)
    expected = static.generate(prompts, max_new_tokens=MAX_NEW)
    budgets = [MAX_NEW, 5, 6]  # ragged: slots hit budget mid-macro-step
    engine = ContinuousServeEngine(model, params, n_slots=2, max_len=MAX_LEN,
                                   eos_id=eos, pad_id=0, macro_step=4)
    report = engine.run(
        [Request(f"r{i}", prompts[i], budgets[i]) for i in range(3)],
        now_fn=lambda: 0.0)
    for i in range(3):
        np.testing.assert_array_equal(
            report.output(f"r{i}", budgets[i]), expected[i, : budgets[i]])


def test_k1_macro_step_degenerates_to_per_token_loop():
    """macro_step=1 must reproduce today's one-sync-per-token behavior
    exactly, and K>1 must emit the same tokens with fewer host syncs."""
    cfg, model, params = _build("tinyllama-1.1b")
    prompts = _prompts(cfg, 3)
    static = ServeEngine(model, params, max_len=MAX_LEN, eos_id=0)
    expected = static.generate(prompts, max_new_tokens=MAX_NEW)
    got_k1, rep_k1 = _run_continuous(model, params, prompts, MAX_NEW,
                                     n_slots=2, macro_step=1)
    got_k8, rep_k8 = _run_continuous(model, params, prompts, MAX_NEW,
                                     n_slots=2, macro_step=8)
    np.testing.assert_array_equal(got_k1, expected)
    np.testing.assert_array_equal(got_k8, expected)
    # K=1 pays ~one sync per generated token on the decode path; K=8
    # amortizes it 8x (both also pay one sync per prefill group)
    assert rep_k8.host_syncs < rep_k1.host_syncs
    assert rep_k1.host_syncs_per_token <= 1.0 + 1e-9


def test_sync_and_dispatch_counters_in_report():
    cfg, model, params = _build("tinyllama-1.1b")
    prompts = _prompts(cfg, 3)
    _, report = _run_continuous(model, params, prompts, MAX_NEW,
                                n_slots=2, macro_step=4)
    d = report.as_dict()
    assert d["host_syncs"] == report.host_syncs > 0
    assert d["device_dispatches"] == report.device_dispatches >= report.host_syncs
    assert d["host_syncs_per_token"] == pytest.approx(
        report.host_syncs / report.generated_tokens)
    # the whole point: fewer host syncs than generated tokens
    assert report.host_syncs < report.generated_tokens


def test_donated_state_is_not_aliased_by_live_buffers():
    """The pooled decode state is donated through prefill/macro-step/reset:
    stale references to pre-donation buffers must raise (in-place update,
    not copy-on-write), and the engine must stay reusable run after run
    (no accidental reuse of a deleted buffer inside the engine)."""
    cfg, model, params = _build("tinyllama-1.1b")
    prompts = _prompts(cfg, 2, seed=11)
    engine = ContinuousServeEngine(model, params, n_slots=2, max_len=MAX_LEN,
                                   eos_id=0, macro_step=4)
    stale = jax.tree.leaves(engine.pool.state)
    reqs = lambda: [Request(f"r{i}", prompts[i], MAX_NEW) for i in range(2)]  # noqa: E731
    rep1 = engine.run(reqs(), now_fn=lambda: 0.0)
    assert any_deleted(stale), "donation did not consume the old state"
    rep2 = engine.run(reqs(), now_fn=lambda: 0.0)  # no RuntimeError on reuse
    for i in range(2):
        np.testing.assert_array_equal(rep1.output(f"r{i}", MAX_NEW),
                                      rep2.output(f"r{i}", MAX_NEW))


def any_deleted(leaves) -> bool:
    for leaf in leaves:
        try:
            np.asarray(leaf)
        except RuntimeError:
            return True
    return False


def test_emitted_count_vectorized():
    from repro.serving import emitted_count

    out = np.array([[5, 7, 0, 9],    # EOS at index 2 -> 3 tokens
                    [1, 2, 3, 4],    # no EOS -> all 4
                    [0, 0, 0, 0]])   # EOS first -> 1
    assert emitted_count(out, eos_id=0) == 3 + 4 + 1
    assert emitted_count(np.zeros((0, 4), np.int32), eos_id=0) == 0


# ---------------------------------------------------------------------------
# Scheduler decisions on the overhead ledger
# ---------------------------------------------------------------------------


def test_ledger_has_site_serve_rows():
    cfg, model, params = _build("tinyllama-1.1b")
    prompts = _prompts(cfg, 3)
    rt = Runtime()
    set_default_runtime(rt)
    _run_continuous(model, params, prompts, MAX_NEW, n_slots=2)
    rows = [e for e in rt.ledger.entries if e.site == "serve"]
    assert rows, "no site=serve rows in the overhead ledger"
    ops = {e.query.get("op") for e in rows}
    assert {"admission", "prefill_chunk"} <= ops
    # the decode composition is now the macro-horizon decision site
    macro = [e for e in rt.ledger.entries if e.site == "serve_macro"]
    assert macro, "no site=serve_macro rows in the overhead ledger"
    measured = [e for e in rows + macro if e.measured_s is not None]
    assert measured, "no measured wall times attached to serve decisions"
    # decisions carry real predicted breakdowns
    assert all(e.predicted_s > 0 for e in rows + macro)


def test_macro_horizon_decision_trades_sync_against_waste():
    """The serve_macro sweep amortizes the host sync over K on uniform
    budgets, but shrinks the horizon when a slot is about to finish."""
    from repro.serving.scheduler import ServeScheduler

    engine = CostEngine()
    cfg = get_config("tinyllama-1.1b").reduced()
    sched = ServeScheduler(cfg, engine, max_len=MAX_LEN)
    k_uniform, dec = sched.macro_horizon((8, 8, 8))
    assert k_uniform > 1  # sync amortization wins on uniform budgets
    assert dec.query.kind == "serve_macro"
    assert dec.baseline.strategy == "K_1"
    k_ragged, _ = sched.macro_horizon((1, 8, 8))
    assert k_ragged <= k_uniform  # imminent finish caps the horizon
    k_pinned, _ = sched.macro_horizon((8, 8, 8), override=1)
    assert k_pinned == 1
    # candidates are FILTERED to the fixed set, never clamped to ad-hoc Ks
    k_small, dec_small = sched.macro_horizon((3,))
    assert k_small in sched.macro_candidates


def test_prefill_chunk_decision_prefers_replay_only_for_non_attn():
    from repro.serving.scheduler import ServeScheduler

    engine = CostEngine()
    attn_cfg = get_config("tinyllama-1.1b").reduced()
    sched = ServeScheduler(attn_cfg, engine, max_len=MAX_LEN)
    chunk, dec = sched.prefill_chunk(64, active_decodes=0)
    assert chunk > 1  # big chunks amortize the weight stream on empty pools
    assert dec.query.kind == "serve"
    rwkv_cfg = get_config("rwkv6-3b").reduced()
    sched_rwkv = ServeScheduler(rwkv_cfg, engine, max_len=MAX_LEN)
    chunk_rwkv, _ = sched_rwkv.prefill_chunk(64, active_decodes=0)
    assert chunk_rwkv == 1  # replay fallback is pinned for recurrent decode


# ---------------------------------------------------------------------------
# Explicit max_len validation (the retired "+ 8" slack)
# ---------------------------------------------------------------------------


def test_overflowing_request_errors_clearly():
    cfg, model, params = _build("tinyllama-1.1b")
    prompts = _prompts(cfg, 1)
    static = ServeEngine(model, params, max_len=PROMPT_LEN + 2, eos_id=0)
    with pytest.raises(ValueError, match="exceeds max_len"):
        static.generate(prompts, max_new_tokens=MAX_NEW)
    cont = ContinuousServeEngine(model, params, n_slots=1,
                                 max_len=PROMPT_LEN + 2, eos_id=0)
    with pytest.raises(ValueError, match="exceeds max_len"):
        cont.run([Request("r0", prompts[0], MAX_NEW)], now_fn=lambda: 0.0)


# ---------------------------------------------------------------------------
# Shared mrope positions helper
# ---------------------------------------------------------------------------


def test_mrope_positions_helper():
    scalar = np.asarray(mrope_positions(2, 3, 5))
    assert scalar.shape == (2, 3, 3)
    np.testing.assert_array_equal(scalar[0, :, 0], [5, 6, 7])
    np.testing.assert_array_equal(scalar[1], scalar[0])
    assert (scalar == scalar[..., :1]).all()  # three planes share the index
    vec = np.asarray(mrope_positions(2, 2, np.array([3, 10], np.int32)))
    np.testing.assert_array_equal(vec[0, :, 0], [3, 4])
    np.testing.assert_array_equal(vec[1, :, 0], [10, 11])
