"""Continuous-batching serving: correctness anchors.

* static-vs-continuous token equivalence (the engine rewrite's invariant),
  across model families (chunked prefill + the chunk-1 replay fallback),
  including slot queueing/reuse (n_slots < n_requests)
* slot reuse after eviction matches a fresh engine (decode-state reset)
* EOS early-stop + deterministic padding in both engines
* scheduler decisions land as site=serve overhead-ledger rows
* explicit max_len validation (no silent slack)
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.costs.engine import CostEngine
from repro.models import build_model
from repro.models.model import mrope_positions
from repro.runtime import Runtime, set_default_runtime
from repro.serving import (
    ContinuousServeEngine,
    Request,
    ServeEngine,
    supports_chunked_prefill,
)

PROMPT_LEN = 7
MAX_NEW = 9
MAX_LEN = PROMPT_LEN + MAX_NEW


@pytest.fixture(autouse=True)
def _fresh_runtime():
    # each test gets its own session (isolated engine + ledger); engines
    # that are not passed one explicitly fall back to this default Runtime
    set_default_runtime(Runtime())
    yield
    set_default_runtime(None)


def _build(arch, key=0, **overrides):
    cfg = get_config(arch).reduced()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(key))
    return cfg, model, params


def _prompts(cfg, b, p=PROMPT_LEN, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, cfg.vocab_size, (b, p)).astype(np.int32)


def _run_continuous(model, params, prompts, max_new, *, n_slots, **kw):
    engine = ContinuousServeEngine(
        model, params, n_slots=n_slots, max_len=MAX_LEN, eos_id=0, **kw)
    reqs = [Request(f"r{i}", prompts[i], max_new) for i in range(len(prompts))]
    report = engine.run(reqs, now_fn=lambda: 0.0)
    return np.stack([report.output(f"r{i}", max_new)
                     for i in range(len(prompts))]), report


# ---------------------------------------------------------------------------
# Token-for-token equivalence with the static baseline
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", [
    "tinyllama-1.1b",       # dense attn -> chunked prefill
    "qwen2-vl-72b",         # mrope positions through the shared helper
    "rwkv6-3b",             # recurrent -> chunk-1 replay fallback
    "recurrentgemma-2b",    # hybrid local ring buffer -> replay fallback
])
def test_continuous_matches_static(arch):
    cfg, model, params = _build(arch)
    prompts = _prompts(cfg, 3)
    static = ServeEngine(model, params, max_len=MAX_LEN, eos_id=0)
    expected = static.generate(prompts, max_new_tokens=MAX_NEW)
    # n_slots < n_requests: forces queueing and slot reuse after eviction
    got, _ = _run_continuous(model, params, prompts, MAX_NEW, n_slots=2)
    np.testing.assert_array_equal(got, expected)


def test_continuous_matches_static_scan_layout():
    """Uniform stacks with >= 4 layers store decode state scanned (slot axis
    1); slot insert/reset must hit the right axis there too."""
    cfg, model, params = _build("tinyllama-1.1b", n_layers=4)
    prompts = _prompts(cfg, 3)
    static = ServeEngine(model, params, max_len=MAX_LEN, eos_id=0)
    expected = static.generate(prompts, max_new_tokens=MAX_NEW)
    got, _ = _run_continuous(model, params, prompts, MAX_NEW, n_slots=2)
    np.testing.assert_array_equal(got, expected)


def test_chunked_prefill_matches_replay():
    """Chunked prefill (multi-token chunks through decode_step) must emit
    the same tokens as the per-token replay it replaces."""
    cfg, model, params = _build("tinyllama-1.1b")
    prompts = _prompts(cfg, 2)
    replay, _ = _run_continuous(model, params, prompts, MAX_NEW,
                                n_slots=2, prefill_chunk=1)
    chunked, _ = _run_continuous(model, params, prompts, MAX_NEW,
                                 n_slots=2, prefill_chunk=4)
    np.testing.assert_array_equal(chunked, replay)


def test_ragged_prompts_match_single_request_runs():
    """Per-slot cache positions: requests with different prompt lengths
    decode concurrently yet match isolated single-request runs."""
    cfg, model, params = _build("tinyllama-1.1b")
    rng = np.random.default_rng(3)
    lens = [4, 7, 10]
    prompts = [rng.integers(1, cfg.vocab_size, (p,)).astype(np.int32)
               for p in lens]
    max_len = max(lens) + MAX_NEW
    engine = ContinuousServeEngine(model, params, n_slots=3,
                                   max_len=max_len, eos_id=0)
    report = engine.run(
        [Request(f"r{i}", prompts[i], MAX_NEW) for i in range(3)],
        now_fn=lambda: 0.0)
    static = ServeEngine(model, params, max_len=max_len, eos_id=0)
    for i in range(3):
        expected = static.generate(prompts[i][None], max_new_tokens=MAX_NEW)[0]
        np.testing.assert_array_equal(report.output(f"r{i}", MAX_NEW), expected)


def test_staggered_arrivals_under_pinned_clock():
    """A frozen test clock with nonzero arrivals must event-skip to the next
    arrival (not sleep forever), and stay token-identical to the baseline."""
    cfg, model, params = _build("tinyllama-1.1b")
    prompts = _prompts(cfg, 3)
    static = ServeEngine(model, params, max_len=MAX_LEN, eos_id=0)
    expected = static.generate(prompts, max_new_tokens=MAX_NEW)
    engine = ContinuousServeEngine(model, params, n_slots=1,
                                   max_len=MAX_LEN, eos_id=0)
    report = engine.run(
        [Request(f"r{i}", prompts[i], MAX_NEW, arrival_s=0.1 * i)
         for i in range(3)],
        now_fn=lambda: 0.0)
    got = np.stack([report.output(f"r{i}", MAX_NEW) for i in range(3)])
    np.testing.assert_array_equal(got, expected)
    assert all(r.ttft_s is not None and r.ttft_s >= 0 for r in report.requests)


# ---------------------------------------------------------------------------
# Slot reuse / reset correctness
# ---------------------------------------------------------------------------


def test_slot_reuse_after_eviction_matches_fresh_engine():
    """A request served on a recycled slot must see no trace of the evicted
    one: its output equals the same request on a fresh engine."""
    cfg, model, params = _build("tinyllama-1.1b")
    prompts = _prompts(cfg, 2, seed=7)
    engine = ContinuousServeEngine(model, params, n_slots=1,
                                   max_len=MAX_LEN, eos_id=0)
    report = engine.run(
        [Request("first", prompts[0], MAX_NEW),
         Request("reused", prompts[1], MAX_NEW)],
        now_fn=lambda: 0.0)
    fresh = ContinuousServeEngine(model, params, n_slots=1,
                                  max_len=MAX_LEN, eos_id=0)
    fresh_report = fresh.run([Request("alone", prompts[1], MAX_NEW)],
                             now_fn=lambda: 0.0)
    np.testing.assert_array_equal(report.output("reused", MAX_NEW),
                                  fresh_report.output("alone", MAX_NEW))


# ---------------------------------------------------------------------------
# EOS handling
# ---------------------------------------------------------------------------


def _pick_eos(model, params, prompts, step=3):
    """Choose as EOS the token the first row actually emits at ``step``
    (so EOS genuinely triggers mid-generation)."""
    probe = ServeEngine(model, params, max_len=MAX_LEN, eos_id=-1)
    base = probe.generate(prompts, max_new_tokens=MAX_NEW)
    return base, int(base[0, step])


def test_static_eos_early_stop_and_padding():
    cfg, model, params = _build("tinyllama-1.1b")
    prompts = _prompts(cfg, 2)
    base, eos = _pick_eos(model, params, prompts)
    engine = ServeEngine(model, params, max_len=MAX_LEN, eos_id=eos, pad_id=0)
    out = engine.generate(prompts, max_new_tokens=MAX_NEW)
    row = out[0]
    k = int(np.flatnonzero(row == eos)[0])
    # tokens before EOS match the unconstrained run, EOS kept, rest padded
    np.testing.assert_array_equal(row[: k + 1], base[0, : k + 1])
    assert np.all(row[k + 1 :] == 0)
    # rows that never emit EOS are unchanged
    if eos not in base[1]:
        np.testing.assert_array_equal(out[1], base[1])


def test_continuous_eos_matches_static():
    cfg, model, params = _build("tinyllama-1.1b")
    prompts = _prompts(cfg, 2)
    _, eos = _pick_eos(model, params, prompts)
    static = ServeEngine(model, params, max_len=MAX_LEN, eos_id=eos, pad_id=0)
    expected = static.generate(prompts, max_new_tokens=MAX_NEW)
    engine = ContinuousServeEngine(model, params, n_slots=2, max_len=MAX_LEN,
                                   eos_id=eos, pad_id=0)
    report = engine.run([Request(f"r{i}", prompts[i], MAX_NEW)
                         for i in range(2)], now_fn=lambda: 0.0)
    got = np.stack([report.output(f"r{i}", MAX_NEW) for i in range(2)])
    np.testing.assert_array_equal(got, expected)
    # the finished request must have stopped early (freed its slot)
    finished = next(r for r in report.requests if eos in r.tokens)
    assert len(finished.tokens) < MAX_NEW or finished.tokens[-1] == eos


# ---------------------------------------------------------------------------
# Scheduler decisions on the overhead ledger
# ---------------------------------------------------------------------------


def test_ledger_has_site_serve_rows():
    cfg, model, params = _build("tinyllama-1.1b")
    prompts = _prompts(cfg, 3)
    rt = Runtime()
    set_default_runtime(rt)
    _run_continuous(model, params, prompts, MAX_NEW, n_slots=2)
    rows = [e for e in rt.ledger.entries if e.site == "serve"]
    assert rows, "no site=serve rows in the overhead ledger"
    ops = {e.query.get("op") for e in rows}
    assert {"admission", "prefill_chunk", "decode_step"} <= ops
    measured = [e for e in rows if e.measured_s is not None]
    assert measured, "no measured wall times attached to serve decisions"
    # decisions carry real predicted breakdowns
    assert all(e.predicted_s > 0 for e in rows)


def test_prefill_chunk_decision_prefers_replay_only_for_non_attn():
    from repro.serving.scheduler import ServeScheduler

    engine = CostEngine()
    attn_cfg = get_config("tinyllama-1.1b").reduced()
    sched = ServeScheduler(attn_cfg, engine, max_len=MAX_LEN)
    chunk, dec = sched.prefill_chunk(64, active_decodes=0)
    assert chunk > 1  # big chunks amortize the weight stream on empty pools
    assert dec.query.kind == "serve"
    rwkv_cfg = get_config("rwkv6-3b").reduced()
    sched_rwkv = ServeScheduler(rwkv_cfg, engine, max_len=MAX_LEN)
    chunk_rwkv, _ = sched_rwkv.prefill_chunk(64, active_decodes=0)
    assert chunk_rwkv == 1  # replay fallback is pinned for recurrent decode


# ---------------------------------------------------------------------------
# Explicit max_len validation (the retired "+ 8" slack)
# ---------------------------------------------------------------------------


def test_overflowing_request_errors_clearly():
    cfg, model, params = _build("tinyllama-1.1b")
    prompts = _prompts(cfg, 1)
    static = ServeEngine(model, params, max_len=PROMPT_LEN + 2, eos_id=0)
    with pytest.raises(ValueError, match="exceeds max_len"):
        static.generate(prompts, max_new_tokens=MAX_NEW)
    cont = ContinuousServeEngine(model, params, n_slots=1,
                                 max_len=PROMPT_LEN + 2, eos_id=0)
    with pytest.raises(ValueError, match="exceeds max_len"):
        cont.run([Request("r0", prompts[0], MAX_NEW)], now_fn=lambda: 0.0)


# ---------------------------------------------------------------------------
# Shared mrope positions helper
# ---------------------------------------------------------------------------


def test_mrope_positions_helper():
    scalar = np.asarray(mrope_positions(2, 3, 5))
    assert scalar.shape == (2, 3, 3)
    np.testing.assert_array_equal(scalar[0, :, 0], [5, 6, 7])
    np.testing.assert_array_equal(scalar[1], scalar[0])
    assert (scalar == scalar[..., :1]).all()  # three planes share the index
    vec = np.asarray(mrope_positions(2, 2, np.array([3, 10], np.int32)))
    np.testing.assert_array_equal(vec[0, :, 0], [3, 4])
    np.testing.assert_array_equal(vec[1, :, 0], [10, 11])
