"""Autotuner tests: cache round-trip (hit/miss/invalidate), prior-only path,
candidate-space pruning (divisor + VMEM filters), ledger recording, fused
matmul epilogue correctness, and the tuned-shape threading through the model
call sites."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.costs.autotune import (
    Autotuner,
    Candidate,
    TuneSpec,
    fmt_config,
    get_tuner,
)
from repro.core.costs.ledger import OverheadLedger
from repro.kernels import ops, ref, tuning

FAKE_TIMES = {1: 3e-4, 2: 2e-4, 4: 1e-4}


def _fake_spec(key="fam/k1", prior_b=1):
    cands = tuple(Candidate({"b": b}, prior_s=t, vmem_bytes=0)
                  for b, t in FAKE_TIMES.items())
    return TuneSpec("fam", key, {"b": prior_b}, cands,
                    make_runner=lambda cfg: (lambda: cfg),
                    query=(("shape", "k1"),))


def _fake_bench(runner, reps):
    return FAKE_TIMES[runner()["b"]]


def _boom_bench(runner, reps):
    raise AssertionError("bench must not run")


# ---------------------------------------------------------------------------
# Cache round-trip
# ---------------------------------------------------------------------------


def test_measured_tune_picks_fastest_and_persists(tmp_path):
    t = Autotuner(cache_dir=tmp_path, measure=True, fingerprint="fp-a",
                  bench=_fake_bench)
    res = t.tune(_fake_spec())
    assert res.source == "measured"
    assert res.config == {"b": 4}  # fastest fake time
    assert res.measured_s == FAKE_TIMES[4]
    assert res.prior_config == {"b": 1}
    assert res.prior_measured_s == FAKE_TIMES[1]
    assert res.speedup_vs_prior == pytest.approx(3.0)
    payload = json.loads((tmp_path / "autotune-fp-a.json").read_text())
    assert payload["fingerprint"] == "fp-a"
    assert payload["entries"]["fam/k1"]["config"] == {"b": 4}


def test_warm_cache_is_measurement_free(tmp_path):
    Autotuner(cache_dir=tmp_path, measure=True, fingerprint="fp-a",
              bench=_fake_bench).tune(_fake_spec())
    warm = Autotuner(cache_dir=tmp_path, measure=True, fingerprint="fp-a",
                     bench=_boom_bench)
    res = warm.tune(_fake_spec())
    assert res.source == "cache"
    assert res.config == {"b": 4}
    assert res.speedup_vs_prior == pytest.approx(3.0)
    assert warm.bench_calls == 0


def test_cache_misses_on_new_key_and_invalidates_on_fingerprint(tmp_path):
    t = Autotuner(cache_dir=tmp_path, measure=True, fingerprint="fp-a",
                  bench=_fake_bench)
    t.tune(_fake_spec())
    # same dir, different key -> miss (prior-only tuner falls back to prior)
    other = Autotuner(cache_dir=tmp_path, measure=False, fingerprint="fp-a",
                      bench=_boom_bench)
    assert other.tune(_fake_spec(key="fam/k2")).source == "prior"
    # same key, different backend fingerprint -> cache invalid
    moved = Autotuner(cache_dir=tmp_path, measure=False, fingerprint="fp-b",
                      bench=_boom_bench)
    assert moved.tune(_fake_spec()).source == "prior"


def test_cached_config_outside_candidate_space_is_rejected(tmp_path):
    t = Autotuner(cache_dir=tmp_path, measure=True, fingerprint="fp-a",
                  bench=_fake_bench)
    t.tune(_fake_spec())
    # shrink the candidate space so the cached winner is no longer valid
    spec = _fake_spec()
    shrunk = TuneSpec(spec.family, spec.key, {"b": 1}, spec.candidates[:2],
                      make_runner=spec.make_runner)
    res = Autotuner(cache_dir=tmp_path, measure=False, fingerprint="fp-a",
                    bench=_boom_bench).tune(shrunk)
    assert res.source == "prior"
    assert res.config == {"b": 1}


def test_memoized_second_call_does_not_rebench(tmp_path):
    t = Autotuner(cache_dir=tmp_path, measure=True, fingerprint="fp-a",
                  bench=_fake_bench)
    t.tune(_fake_spec())
    calls = t.bench_calls
    assert t.tune(_fake_spec()).source == "measured"
    assert t.bench_calls == calls


# ---------------------------------------------------------------------------
# Prior-only path (measurement disabled — the tier-1 default)
# ---------------------------------------------------------------------------


def test_prior_only_never_measures_or_persists(tmp_path):
    t = Autotuner(cache_dir=tmp_path, measure=False, fingerprint="fp-a",
                  bench=_boom_bench)
    res = t.tune(_fake_spec(prior_b=2))
    assert res.source == "prior"
    assert res.config == {"b": 2}
    assert res.measured_s is None
    assert not (tmp_path / "autotune-fp-a.json").exists()


def test_failing_candidates_fall_back_to_prior(tmp_path):
    def broken_bench(runner, reps):
        raise RuntimeError("backend exploded")

    t = Autotuner(cache_dir=tmp_path, measure=True, fingerprint="fp-a",
                  bench=broken_bench)
    res = t.tune(_fake_spec())
    assert res.source == "prior"
    assert res.config == {"b": 1}


def test_measured_tune_records_prior_and_tuned_ledger_rows(tmp_path):
    ledger = OverheadLedger()
    t = Autotuner(cache_dir=tmp_path, measure=True, fingerprint="fp-a",
                  bench=_fake_bench, ledger=ledger)
    t.tune(_fake_spec())
    assert [e.note for e in ledger.entries] == ["prior", "tuned"]
    assert all(e.site == "autotune" for e in ledger.entries)
    assert all(e.measured_s is not None for e in ledger.entries)
    prior, tuned = ledger.entries
    assert tuned.measured_s <= prior.measured_s


def test_default_tuner_is_prior_only(monkeypatch):
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    assert Autotuner().measure is False
    with pytest.warns(DeprecationWarning):  # the back-compat shim
        assert isinstance(get_tuner(), Autotuner)


# ---------------------------------------------------------------------------
# Candidate spaces: divisor + VMEM filters
# ---------------------------------------------------------------------------


def test_matmul_candidates_divide_dims_and_fit_vmem():
    budget = tuning.vmem_budget()
    for m, n, k in [(128, 128, 128), (640, 640, 128), (8192, 8192, 8192)]:
        prior, cands = tuning.matmul_candidates(m, n, k, 4)
        assert cands
        for c in cands:
            assert m % c.config["bm"] == 0
            assert n % c.config["bn"] == 0
            assert k % c.config["bk"] == 0
            assert c.vmem_bytes <= budget
        assert any(c.config == prior for c in cands)


def test_matmul_default_path_handles_non_divisor_heuristic(rng):
    # m=640: pick_block_shape proposes bm=512 which does not divide 640; the
    # tuner's divisor filter must fall back to a valid config
    a = jax.random.normal(rng, (640, 128), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(rng, 1), (128, 256), jnp.float32)
    out = ops.matmul(a, b, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul_ref(a, b)),
                               atol=1e-4, rtol=1e-4)


def test_sort_block_rows_respects_vmem_budget():
    budget = tuning.vmem_budget()
    big_n = 1 << 22  # 4M fp32 elements/row: 8 rows would be 384 MB resident
    prior, cands = tuning.sort_candidates(8, big_n, 4)
    from repro.kernels.bitonic_sort import sort_working_set_bytes

    assert sort_working_set_bytes(8, big_n, 4) > budget  # old loop's choice
    assert prior["block_rows"] < 8
    assert sort_working_set_bytes(prior["block_rows"], big_n, 4) <= budget
    # and the small-n prior matches the historical loop exactly
    small_prior, _ = tuning.sort_candidates(16, 1024, 4)
    assert small_prior == {"block_rows": 8}


def test_flash_and_wkv_priors_match_historical_defaults():
    fp, fcands = tuning.flash_candidates(8, 256, 256, 64, 4, causal=True)
    assert fp == {"block_q": 128, "block_kv": 128}
    assert all(c.vmem_bytes <= tuning.vmem_budget() for c in fcands)
    wp, wcands = tuning.wkv_candidates(4, 128, 8, 4)
    assert wp == {"chunk": 64}
    assert all(c.config["chunk"] <= 128 for c in wcands)


def test_fmt_config_is_stable():
    assert fmt_config({"bn": 2, "bm": 1}) == "bm=1,bn=2"


# ---------------------------------------------------------------------------
# Fused matmul epilogue vs ref.py
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("activation", [None, "relu", "gelu", "silu"])
def test_fused_epilogue_matches_ref(rng, activation):
    k1, k2, k3 = jax.random.split(rng, 3)
    a = jax.random.normal(k1, (100, 60), jnp.float32)
    b = jax.random.normal(k2, (60, 72), jnp.float32)
    bias = jax.random.normal(k3, (72,), jnp.float32)
    out = ops.matmul(a, b, bias=bias, activation=activation, interpret=True)
    expect = ref.matmul_fused_ref(a, b, bias=bias, activation=activation)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


def test_fused_epilogue_out_dtype_cast(rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    a = jax.random.normal(k1, (128, 128), jnp.float32)
    b = jax.random.normal(k2, (128, 128), jnp.float32)
    bias = jax.random.normal(k3, (128,), jnp.float32)
    out = ops.matmul(a, b, bias=bias, activation="gelu",
                     out_dtype=jnp.bfloat16, interpret=True)
    assert out.dtype == jnp.bfloat16
    expect = ref.matmul_fused_ref(a, b, bias=bias, activation="gelu",
                                  out_dtype=jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=3e-2, rtol=3e-2)


def test_fused_epilogue_multi_k_step(rng):
    """Epilogue must run once, after the LAST K step's accumulation."""
    a = jax.random.normal(rng, (128, 512), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(rng, 1), (512, 128), jnp.float32)
    bias = jnp.full((128,), 0.5, jnp.float32)
    out = ops.matmul(a, b, bias=bias, activation="relu",
                     block_shape=(128, 128, 128), interpret=True)
    expect = ref.matmul_fused_ref(a, b, bias=bias, activation="relu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-4, rtol=1e-5)


def test_unknown_activation_rejected(rng):
    a = jnp.ones((128, 128), jnp.float32)
    with pytest.raises(ValueError):
        ops.matmul(a, a, activation="softmax", interpret=True)


# ---------------------------------------------------------------------------
# Padding/masking regressions surfaced by the tuner routing
# ---------------------------------------------------------------------------


def test_flash_non_causal_padded_kv_is_masked(rng):
    """KV zero-padded to the block multiple must not leak exp(0) mass into
    the softmax denominator (non-causal has no causal mask to hide it)."""
    from repro.models.attention import dense_attention

    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (1, 192, 2, 32))
    k = jax.random.normal(ks[1], (1, 192, 2, 32))
    v = jax.random.normal(ks[2], (1, 192, 2, 32))
    out = ops.flash_attention(q, k, v, causal=False, block_q=128,
                              block_kv=128, interpret=True)
    expect = dense_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-3, rtol=2e-3)


def test_sort_integer_dtype(rng):
    x = jax.random.randint(rng, (100,), -1000, 1000, dtype=jnp.int32)
    out = ops.sort(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x)))


def test_attention_unknown_impl_rejected(rng):
    from repro.models.attention import attention

    q = jnp.ones((1, 16, 2, 8))
    with pytest.raises(ValueError):
        attention(q, q, q, impl="pallas")


# ---------------------------------------------------------------------------
# Tuned shapes reach the model call sites
# ---------------------------------------------------------------------------


def test_attention_flash_impl_matches_dense(rng):
    from repro.models.attention import attention, dense_attention

    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    out = attention(q, k, v, causal=True, impl="flash", interpret=True)
    expect = dense_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-3, rtol=2e-3)
    # explicit blocks are threaded through, not overridden by the tuner
    out2 = attention(q, k, v, causal=True, impl="flash", block_q=64,
                     block_kv=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               atol=1e-5, rtol=1e-5)
    with pytest.raises(ValueError):
        attention(q, k, v, impl="flash", window=32)


def test_rwkv_pallas_backend_matches_xla(rng):
    from repro.models.rwkv import rwkv_time_mix, rwkv_time_mix_init

    params = rwkv_time_mix_init(rng, 32, 8)
    x = jax.random.normal(jax.random.fold_in(rng, 2), (2, 40, 32))
    out_x, _ = rwkv_time_mix(params, x, 8, backend="xla")
    out_p, _ = rwkv_time_mix(params, x, 8, backend="pallas", chunk=16)
    np.testing.assert_allclose(np.asarray(out_x), np.asarray(out_p),
                               atol=1e-3, rtol=1e-3)


def test_dispatch_and_sort_kernel_paths(rng):
    from repro.core import adaptive_matmul, distributed_sort

    a = jax.random.normal(rng, (96, 64), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(rng, 1), (64, 80), jnp.float32)
    out = adaptive_matmul(a, b, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               atol=1e-4, rtol=1e-4)
    x = jax.random.normal(rng, (300,))
    out, report = distributed_sort(x, local_sort="pallas")
    assert report.strategy == "serial"
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x)))


# ---------------------------------------------------------------------------
# Real measurement (slow: excluded from tier-1, run with -m slow)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_real_measured_tune_roundtrip(tmp_path):
    t = Autotuner(cache_dir=tmp_path, measure=True, reps=2)
    res = tuning.tune_matmul(128, 128, 128, jnp.float32, interpret=True,
                             tuner=t)
    assert res.source == "measured"
    assert res.measured_s is not None and res.measured_s > 0
    warm = Autotuner(cache_dir=tmp_path, measure=True, bench=_boom_bench)
    res2 = tuning.tune_matmul(128, 128, 128, jnp.float32, interpret=True,
                              tuner=warm)
    assert res2.source == "cache"
    assert res2.config == res.config
