"""Tests for the paper's core: overhead model, crossover behaviour, fork-join
dispatch, dependency analysis, sharding planner (single-device parts; the
multi-device execution paths are covered by test_distributed.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import SHAPES, get_config, list_configs
from repro.core import (
    OverheadModel,
    adaptive_matmul,
    analyze_dependencies,
    decide_matmul,
    distributed_sort,
    plan_model,
)

OM = OverheadModel()


# ---------------------------------------------------------------------------
# Overhead model properties (hypothesis)
# ---------------------------------------------------------------------------


@given(
    n=st.integers(min_value=8, max_value=16384),
    chips=st.sampled_from([2, 4, 16, 64, 256]),
)
@settings(max_examples=60, deadline=None)
def test_cost_positive_and_monotone_in_size(n, chips):
    c1 = OM.matmul_cost(n, n, n, chips=chips, strategy="shard_k")
    c2 = OM.matmul_cost(2 * n, 2 * n, 2 * n, chips=chips, strategy="shard_k")
    assert c1.total > 0
    assert c2.total > c1.total  # more work, more time


@given(n=st.integers(min_value=64, max_value=8192))
@settings(max_examples=40, deadline=None)
def test_parallel_compute_term_scales_down(n):
    serial = OM.matmul_cost(n, n, n, strategy="serial")
    par = OM.matmul_cost(n, n, n, chips=64, strategy="shard_m")
    assert par.compute < serial.compute
    assert par.compute == pytest.approx(serial.compute / 64, rel=1e-6)


def test_crossover_exists_and_is_paper_scale():
    """Paper: parallelization pays only above a problem-size threshold.
    On TPU v5e the matmul crossover lands in the thousands (the paper found
    ~1000 on multicore CPU; ICI costs more relative to MXU compute)."""
    for chips in (2, 8, 64, 256):
        xo = OM.matmul_crossover_order(chips)
        assert 500 < xo < 50000, (chips, xo)
        # below crossover serial wins, above parallel wins
        below = decide_matmul(xo // 2, xo // 2, xo // 2, chips=chips)
        above = decide_matmul(2 * xo, 2 * xo, 2 * xo, chips=chips)
        assert below.chosen.strategy == "serial"
        assert above.chosen.strategy != "serial"
        assert above.predicted_speedup > 1.0


def test_sort_crossover_larger_than_matmul():
    """Sorting is bandwidth/latency bound — its crossover sits far above the
    paper's 1000-element CPU threshold on this hardware."""
    xo = OM.sort_crossover_n(8)
    assert xo > 10000


def test_collective_time_properties():
    assert OM.collective_time(0, 64) == 0.0
    assert OM.collective_time(1 << 20, 1) == 0.0
    t_ar = OM.collective_time(1 << 30, 64, "all_reduce")
    t_ag = OM.collective_time(1 << 30, 64, "all_gather")
    assert t_ar > t_ag  # all-reduce moves 2x the bytes of all-gather


def test_moe_dispatch_tradeoff_flips_with_topk():
    """High top_k favors replicated-psum; tiny top_k favors all-to-all."""
    lo = OM.moe_dispatch_cost(65536, 4096, top_k=1, ep_shards=16)
    hi = OM.moe_dispatch_cost(65536, 4096, top_k=8, ep_shards=16)
    assert lo["all_to_all"] < lo["replicated_psum"]
    assert hi["replicated_psum"] < hi["all_to_all"]


def test_scan_chunk_choice_bounded():
    c = OM.best_scan_chunk(4096, batch=8, heads=40, head_dim=64)
    assert c in (16, 32, 64, 128, 256)


# ---------------------------------------------------------------------------
# Fork-join dispatch (serial path on 1 device)
# ---------------------------------------------------------------------------


def test_adaptive_matmul_serial_correct(rng):
    a = jax.random.normal(rng, (96, 64))
    b = jax.random.normal(jax.random.fold_in(rng, 1), (64, 80))
    out, rep = adaptive_matmul(a, b, return_report=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b), atol=1e-5)
    assert rep.chosen.strategy == "serial"  # 1 device -> serial always


def test_matmul_chain_dispatch(rng):
    from repro.core.dispatch import matmul_chain

    ms = [jax.random.normal(jax.random.fold_in(rng, i), s)
          for i, s in enumerate([(8, 32), (32, 4), (4, 64), (64, 16)])]
    out = matmul_chain(ms)
    ref = ms[0] @ ms[1] @ ms[2] @ ms[3]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_distributed_sort_serial_path(rng):
    x = jax.random.normal(rng, (1000,))
    out, rep = distributed_sort(x)
    assert rep.strategy == "serial"
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x)))


# ---------------------------------------------------------------------------
# Dependency analysis
# ---------------------------------------------------------------------------


def test_dependency_serial_chain_has_low_parallelism():
    def chain(x):
        for _ in range(8):
            x = x @ x
        return x

    rep = analyze_dependencies(chain, jnp.ones((32, 32)))
    assert rep.parallelism < 1.5  # fully sequential


def test_dependency_parallel_branches_detected():
    def branches(x):
        return sum(jnp.dot(x + i, x) for i in range(8))

    rep = analyze_dependencies(branches, jnp.ones((32, 32)))
    assert rep.parallelism > 4.0


def test_dependency_counts_scan_work():
    def scanned(x):
        def body(c, _):
            return c @ x, None
        out, _ = jax.lax.scan(body, x, None, length=16)
        return out

    rep_1 = analyze_dependencies(lambda x: x @ x, jnp.ones((32, 32)))
    rep_16 = analyze_dependencies(scanned, jnp.ones((32, 32)))
    assert rep_16.total_flops >= 14 * rep_1.total_flops


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", list_configs())
def test_planner_produces_feasible_plans(arch):
    cfg = get_config(arch)
    for shape_name in ("train_4k", "decode_32k"):
        plan = plan_model(cfg, SHAPES[shape_name], {"data": 16, "model": 16})
        assert plan.decisions
        assert plan.fits_hbm, f"{arch} {shape_name}: {plan.hbm_per_chip/1e9:.1f}GB/chip"
        assert plan.rnn_chunk in (16, 32, 64, 128, 256)


def test_planner_prefers_tp_for_big_ffn_replicate_for_tiny():
    """The paper's crossover, at the layer level."""
    big = get_config("qwen2-vl-72b")
    plan = plan_model(big, SHAPES["train_4k"], {"data": 16, "model": 16})
    ffn = next(d for d in plan.decisions if d.site == "ffn")
    assert ffn.choice == "shard_model"
    # a decode microbatch of 1 token on a tiny model: TP cannot amortize
    tiny = get_config("tinyllama-1.1b")
    from repro.configs.base import ShapeSpec

    plan2 = plan_model(tiny, ShapeSpec("tiny_decode", 128, 16, "decode"),
                       {"data": 16, "model": 16})
    ffn2 = next(d for d in plan2.decisions if d.site == "ffn")
    assert ffn2.rep_cost < float("inf")
