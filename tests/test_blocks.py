"""Block-level correctness: every fancy/parallel form is checked against a
naive sequential oracle (the paper's serial-vs-parallel equivalence, applied
as a test invariant)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn_lib
from repro.models import ffn as ffn_lib
from repro.models import rglru as rglru_lib
from repro.models import rwkv as rwkv_lib


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _qkv(key, b=2, s=64, hq=4, hkv=2, hd=16, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, hq, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("s,chunk", [(64, 16), (96, 32), (128, 128)])
def test_chunked_matches_dense(rng, s, chunk):
    q, k, v = _qkv(rng, s=s)
    ref = attn_lib.dense_attention(q, k, v, causal=True)
    out = attn_lib.chunked_attention(q, k, v, causal=True, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("window", [8, 16, 64])
def test_local_matches_dense_windowed(rng, window):
    q, k, v = _qkv(rng, s=96)
    ref = attn_lib.dense_attention(q, k, v, causal=True, window=window)
    out = attn_lib.local_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


def test_gqa_equals_repeated_mha(rng):
    """GQA with kv heads repeated == MHA."""
    q, k, v = _qkv(rng, hq=4, hkv=2)
    out_gqa = attn_lib.dense_attention(q, k, v)
    k_rep = jnp.repeat(k, 2, axis=2)
    v_rep = jnp.repeat(v, 2, axis=2)
    out_mha = attn_lib.dense_attention(q, k_rep, v_rep)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha), atol=1e-5, rtol=1e-5)


def test_decode_matches_prefill_lastpos(rng):
    """decode_attention at position t == dense attention row t."""
    q, k, v = _qkv(rng, s=32)
    full = attn_lib.dense_attention(q, k, v, causal=True)
    smax = 48
    kc = jnp.zeros((2, smax, 2, 16)).at[:, :32].set(k)
    vc = jnp.zeros((2, smax, 2, 16)).at[:, :32].set(v)
    out = attn_lib.decode_attention(q[:, -1:], kc, vc, jnp.int32(32))
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, -1]), atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# RWKV6 chunked WKV vs sequential recurrence
# ---------------------------------------------------------------------------


def _wkv_sequential(r, k, v, logw, u):
    b, s, h, n = r.shape
    S = jnp.zeros((b, h, n, n))
    outs = []
    for t in range(s):
        rt, kt, vt, wt = r[:, t], k[:, t], v[:, t], jnp.exp(logw[:, t])
        o = jnp.einsum("bhn,bhnm->bhm", rt, S) + jnp.einsum(
            "bhn,hn,bhn,bhm->bhm", rt, u, kt, vt
        )
        S = wt[..., None] * S + jnp.einsum("bhn,bhm->bhnm", kt, vt)
        outs.append(o)
    return jnp.stack(outs, axis=1), S


@pytest.mark.parametrize("s,chunk", [(16, 4), (33, 8), (64, 64), (40, 16)])
def test_wkv_chunked_matches_sequential(rng, s, chunk):
    b, h, n = 2, 3, 8
    ks = jax.random.split(rng, 4)
    r = jax.random.normal(ks[0], (b, s, h, n))
    k = jax.random.normal(ks[1], (b, s, h, n))
    v = jax.random.normal(ks[2], (b, s, h, n))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, n)))  # strong + weak decay
    u = jnp.full((h, n), 0.3)
    ref, S_ref = _wkv_sequential(r, k, v, logw, u)
    out, S_out = rwkv_lib.wkv_chunked(r, k, v, logw, u, None, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(S_out), np.asarray(S_ref), atol=1e-4, rtol=1e-4)


def test_wkv_extreme_decay_stable(rng):
    """Log-space chunking must survive near-zero decay (w -> 0)."""
    b, s, h, n = 1, 32, 1, 4
    ks = jax.random.split(rng, 3)
    r = jax.random.normal(ks[0], (b, s, h, n))
    k = jax.random.normal(ks[1], (b, s, h, n))
    v = jax.random.normal(ks[2], (b, s, h, n))
    logw = jnp.full((b, s, h, n), -50.0)  # catastrophic decay
    u = jnp.zeros((h, n))
    out, S = rwkv_lib.wkv_chunked(r, k, v, logw, u, None, chunk=8)
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(np.asarray(S)).all()


def test_wkv_step_matches_chunked(rng):
    b, s, h, n = 2, 12, 2, 8
    ks = jax.random.split(rng, 4)
    r = jax.random.normal(ks[0], (b, s, h, n))
    k = jax.random.normal(ks[1], (b, s, h, n))
    v = jax.random.normal(ks[2], (b, s, h, n))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, n)) - 1.0)
    u = jnp.full((h, n), 0.1)
    ref, S_ref = rwkv_lib.wkv_chunked(r, k, v, logw, u, None, chunk=4)
    S = jnp.zeros((b, h, n, n))
    outs = []
    for t in range(s):
        o, S = rwkv_lib.wkv_step(r[:, t:t+1], k[:, t:t+1], v[:, t:t+1], logw[:, t:t+1], u, S)
        outs.append(o[:, 0])
    out = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref), atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# RG-LRU associative scan vs sequential
# ---------------------------------------------------------------------------


def test_rglru_parallel_matches_sequential(rng):
    d, w, b, s = 16, 16, 2, 40
    params = rglru_lib.rglru_init(rng, d, w)
    x = jax.random.normal(rng, (b, s, d)) * 0.5
    out_par, _ = rglru_lib.rglru_apply(params, x, state=None)
    # sequential path via the decode branch
    st = rglru_lib.rglru_init_state(b, w)
    out_seq, _ = rglru_lib.rglru_apply(params, x, state=st)
    np.testing.assert_allclose(np.asarray(out_par), np.asarray(out_seq), atol=1e-5, rtol=1e-5)


def test_rglru_step_streaming(rng):
    """Feeding tokens one at a time == full-sequence processing."""
    d, w, b, s = 8, 8, 1, 10
    params = rglru_lib.rglru_init(rng, d, w)
    x = jax.random.normal(rng, (b, s, d)) * 0.5
    full, _ = rglru_lib.rglru_apply(params, x, state=None)
    st = rglru_lib.rglru_init_state(b, w)
    outs = []
    for t in range(s):
        o, st = rglru_lib.rglru_apply(params, x[:, t:t+1], state=st)
        outs.append(o[:, 0])
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1)), np.asarray(full), atol=1e-5, rtol=1e-5
    )


# ---------------------------------------------------------------------------
# MoE: dense oracle properties
# ---------------------------------------------------------------------------


def test_moe_dense_topk_weights_sum_to_one(rng):
    d, f, e = 8, 16, 4
    params = ffn_lib.moe_init(rng, d, f, e, "swiglu")
    x = jax.random.normal(rng, (2, 6, d))
    y, aux = ffn_lib.moe_dense(params, x, top_k=2, activation="swiglu")
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0.0


def test_moe_topk1_equals_best_expert(rng):
    """With top_k=1, output == the single selected expert's FFN."""
    d, f, e = 4, 8, 3
    params = ffn_lib.moe_init(rng, d, f, e, "swiglu")
    x = jax.random.normal(rng, (1, 5, d))
    y, _ = ffn_lib.moe_dense(params, x, top_k=1, activation="swiglu")
    t = x.reshape(-1, d)
    logits = t @ params["router"]
    ids = np.asarray(jnp.argmax(logits, -1))
    for i, eid in enumerate(ids):
        p_e = {
            "w_in": params["w_in"][eid],
            "w_gate": params["w_gate"][eid],
            "w_out": params["w_out"][eid],
        }
        ref = ffn_lib.ffn_apply(p_e, t[i], "swiglu")
        np.testing.assert_allclose(np.asarray(y.reshape(-1, d)[i]), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)
