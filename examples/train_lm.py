"""End-to-end training driver: train a ~100M-param LM for a few hundred
steps with checkpointing, through the public Runtime API (deliverable b's
end-to-end example).

The default preset (``--preset small``, ~25M params) finishes in minutes on
CPU CI; ``--preset 100m`` is the full deliverable configuration and runs in
under an hour on CPU (or seconds per hundred steps on a real TPU host).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import dataclasses

import repro

PRESETS = {
    # (layers, d_model, heads, kv, d_ff, vocab)
    "small": (8, 256, 8, 4, 1024, 8192),  # ~25M params
    "100m": (12, 768, 12, 4, 2048, 32000),  # ~100M params
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="small")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    nl, d, h, kv, ff, v = PRESETS[args.preset]
    cfg = dataclasses.replace(
        repro.get_config("tinyllama-1.1b"),
        name=f"train-lm-{args.preset}", n_layers=nl, d_model=d, n_heads=h,
        n_kv_heads=kv, head_dim=d // h, d_ff=ff, vocab_size=v, dtype="float32",
        max_seq_len=args.seq,
    )
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    rt = repro.Runtime(repro.RuntimeConfig.from_env())
    loop = repro.TrainLoopConfig(
        optimizer=repro.AdamWConfig(lr=1e-3),
        warmup_steps=args.steps // 10, total_steps=args.steps,
    )
    res = rt.train(cfg, loop, steps=args.steps, batch=args.batch,
                   seq=args.seq, seed=args.seed, ckpt_dir=args.ckpt_dir,
                   ckpt_every=100, resume=args.resume, log_every=25)
    tok = res.steps_run * args.batch * args.seq
    tok_s = tok / res.wall_s if res.wall_s > 0 else 0.0
    print(f"finished {res.steps_run} steps in {res.wall_s:.1f}s "
          f"({tok_s:.0f} tok/s); checkpoint at {args.ckpt_dir}")


if __name__ == "__main__":
    main()
