"""End-to-end training driver: train a ~100M-param LM for a few hundred
steps with checkpointing (deliverable b's end-to-end example).

The default preset (``--preset small``, ~25M params) finishes in minutes on
CPU CI; ``--preset 100m`` is the full deliverable configuration and runs in
under an hour on CPU (or seconds per hundred steps on a real TPU host).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import latest_step, restore, save
from repro.configs import get_config
from repro.data import SyntheticLMData
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.training import TrainLoopConfig, init_train_state, make_train_step

PRESETS = {
    # (layers, d_model, heads, kv, d_ff, vocab)
    "small": (8, 256, 8, 4, 1024, 8192),  # ~25M params
    "100m": (12, 768, 12, 4, 2048, 32000),  # ~100M params
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="small")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    nl, d, h, kv, ff, v = PRESETS[args.preset]
    cfg = dataclasses.replace(
        get_config("tinyllama-1.1b"),
        name=f"train-lm-{args.preset}", n_layers=nl, d_model=d, n_heads=h,
        n_kv_heads=kv, head_dim=d // h, d_ff=ff, vocab_size=v, dtype="float32",
        max_seq_len=args.seq,
    )
    model = build_model(cfg)
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    loop = TrainLoopConfig(
        optimizer=AdamWConfig(lr=1e-3), warmup_steps=args.steps // 10,
        total_steps=args.steps,
    )
    ds = SyntheticLMData(cfg, seq_len=args.seq, global_batch=args.batch)
    state = init_train_state(model, jax.random.PRNGKey(0), loop)
    start = 0
    if args.resume:
        last = latest_step(args.ckpt_dir)
        if last:
            state = restore(args.ckpt_dir, last, state)
            start = last
            print(f"resumed at {start}")

    step = jax.jit(make_train_step(model, loop))
    t0 = time.time()
    for i in range(start, args.steps):
        state, metrics = step(state, ds.batch_at(i))
        if i % 25 == 0 or i == args.steps - 1:
            tok_s = (i - start + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                  f"({tok_s:.0f} tok/s)")
        if (i + 1) % 100 == 0:
            save(args.ckpt_dir, i + 1, state)
    save(args.ckpt_dir, args.steps, state)
    print(f"finished {args.steps - start} steps in {time.time() - t0:.1f}s; "
          f"checkpoint at {args.ckpt_dir}")


if __name__ == "__main__":
    main()
