"""Distributed sample sort demo — the paper's quicksort study on a mesh.

Standalone script: owns the process, so it forces 8 placeholder devices
(like the dry-run does with 512) BEFORE importing jax.

Run:  PYTHONPATH=src python examples/distributed_sort.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import OverheadModel  # noqa: E402
from repro.core.sort import PIVOT_STRATEGIES, distributed_sort  # noqa: E402


def main():
    mesh = jax.make_mesh((8,), ("data",))
    om = OverheadModel()
    print(f"devices: {len(jax.devices())}; "
          f"v5e sort crossover @8 chips: n >= {om.sort_crossover_n(8)}")

    x = jnp.exp(jax.random.normal(jax.random.PRNGKey(0), (20_000,)))  # skewed
    ref = np.sort(np.asarray(x))

    print(f"{'pivot':>10s} {'correct':>8s} {'imbalance':>10s}   (paper Table 3: "
          f"random pivots worst)")
    for pivot in PIVOT_STRATEGIES:
        out, rep = distributed_sort(x, mesh, "data", pivot=pivot,
                                    force_parallel=True)
        ok = np.array_equal(np.asarray(out), ref)
        print(f"{pivot:>10s} {str(ok):>8s} {rep.imbalance:>10.2f}")

    # the overhead-managed path: small n -> serial, huge n -> parallel
    small, rep_s = distributed_sort(jnp.arange(100.0)[::-1], mesh, "data")
    print(f"\nadaptive: n=100 -> {rep_s.strategy} (overhead says serial wins)")


if __name__ == "__main__":
    main()
