"""Overhead-managed dispatch demo — the paper's core idea end to end:

1. crossover analysis (paper Fig. 2) for matmul and sorting on TPU v5e,
2. fork-join adaptive matmul + matrix-chain dispatch,
3. dependency analysis (work/span) of model blocks,
4. the overhead-driven sharding plan for every assigned architecture.

Run:  PYTHONPATH=src python examples/adaptive_dispatch.py
"""

import jax
import jax.numpy as jnp

import repro
from repro.configs import SHAPES
from repro.core import adaptive_matmul, analyze_dependencies, decide_matmul


def main():
    # one explicit session; from_env keeps the legacy env-var behavior
    # (REPRO_CALIBRATE=1 calibrates the engine to this backend)
    rt = repro.Runtime(repro.RuntimeConfig.from_env())
    engine = rt.engine

    print(f"== crossovers on {engine.hw.name} "
          f"(paper: matmul order ~1000 on multicore CPU) ==")
    for chips in (8, 64, 256):
        print(f"  {chips:3d} chips: matmul order >= "
              f"{engine.matmul_crossover_order(chips):6d}, "
              f"sort n >= {engine.sort_crossover_n(chips)}")

    print("\n== adaptive matmul decisions ==")
    for n in (256, 2048, 16384):
        rep = decide_matmul(n, n, n, chips=256, engine=engine)
        print(f"  {n:6d}^3 -> {rep.chosen.strategy:8s} "
              f"predicted speedup {rep.predicted_speedup:5.2f}x "
              f"dominant={rep.chosen.dominant()}")

    out = adaptive_matmul(jnp.ones((64, 32)), jnp.ones((32, 16)))
    print(f"  executed 64x32 @ 32x16 serially -> {out.shape}")

    print("\n== dependency analysis (work/span) ==")
    cfg = repro.get_config("tinyllama-1.1b").reduced()
    model = repro.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 32), jnp.int32)}
    rep = analyze_dependencies(lambda p, b: model.loss(p, b)[0], params, batch)
    print(f"  tinyllama loss: {rep.summary()}")

    print("\n== overhead-driven sharding plans (16x16 mesh, train_4k) ==")
    for arch in repro.list_configs():
        plan = rt.plan(repro.get_config(arch), SHAPES["train_4k"],
                       {"data": 16, "model": 16})
        print(f"--- {arch}")
        print(plan.summary())

    print("\n== cost ledger (every decision above; cache stats) ==")
    print(f"  decision cache: {engine.cache_stats()}")
    print(engine.ledger.table(max_rows=12))


if __name__ == "__main__":
    main()
