"""Quickstart: the whole public API through one ``import repro``.

Build an assigned architecture at smoke scale, train + checkpoint + resume
through the Runtime, then serve the trained weights both ways (static
lockstep baseline vs continuous batching) and verify they agree token for
token — with every fork-join decision the session made on one ledger.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import numpy as np

import repro


def main():
    print("assigned architectures:", ", ".join(repro.list_configs()))
    rt = repro.Runtime()  # the session: engine + caches + mesh + ledger
    cfg = repro.get_config("tinyllama-1.1b").reduced()

    # --- train (the Runtime owns the plan, the loop, and checkpoints) ---
    loop = repro.TrainLoopConfig(optimizer=repro.AdamWConfig(lr=3e-3),
                                 warmup_steps=5, total_steps=60)
    with tempfile.TemporaryDirectory() as d:
        res = rt.train(cfg, loop, steps=30, batch=8, seq=32,
                       ckpt_dir=d, ckpt_every=30, log_every=10)
        # --- checkpoint / restore: resuming at the saved step is a no-op
        resumed = rt.train(cfg, loop, steps=30, batch=8, seq=32,
                           ckpt_dir=d, resume=True, log_every=0)
        assert resumed.start_step == 30 and resumed.steps_run == 0
        print("checkpoint roundtrip ok")
    params = res.state["params"]

    # --- serve (static batch; eos_id=-1 keeps the demo un-truncated) ---
    prompts = np.arange(1, 9, dtype=np.int32).reshape(2, 4)
    trace = lambda: [repro.Request(f"r{i}", prompts[i], 8)  # noqa: E731
                     for i in range(2)]
    static = rt.serve(cfg, trace(), mode="static", params=params,
                      max_len=64, eos_id=-1)
    print("generated:", [static.outputs[f"r{i}"].tolist() for i in range(2)])

    # --- serve (continuous batching: slots, chunked prefill, scheduler) ---
    cont = rt.serve(cfg, trace(), mode="continuous", params=params,
                    slots=2, max_len=64, eos_id=-1)
    assert all(np.array_equal(cont.outputs[f"r{i}"], static.outputs[f"r{i}"])
               for i in range(2))
    print(f"continuous batching matched token-for-token "
          f"({cont.generated_tokens} tokens, {cont.tok_per_s:.0f} tok/s)")

    # --- serve with streaming: tokens surface incrementally at macro-step
    # boundaries (zero added device syncs), TTFT stamped at the first
    # burst.  frontend=2 would additionally run validation + detok in
    # pinned worker processes (the serve_ipc cost site decides whether
    # that is worth the queue round trips).
    streamed = rt.serve(cfg, trace(), mode="continuous", params=params,
                        slots=2, max_len=64, eos_id=-1, stream=True)
    for rid in sorted(streamed.stream.rids()):
        bursts = [list(ev.tokens) for ev in streamed.stream.events(rid)
                  if ev.tokens]
        print(f"streamed {rid}: {bursts} "
              f"(ttft={streamed.stream.first_token_s(rid)*1e3:.1f}ms)")
        assert streamed.stream.tokens(rid) == \
            streamed.outputs[rid].tolist()

    # --- one session, one ledger: plan + serve decisions, pred-vs-meas ---
    print(rt.ledger.report(max_rows=8))


if __name__ == "__main__":
    main()
