"""Quickstart: build an assigned architecture at smoke scale, train a few
steps, checkpoint, restore, and decode — the whole public API in 60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax
import numpy as np

from repro.checkpoint import latest_step, restore, save
from repro.configs import get_config, list_configs
from repro.data import SyntheticLMData
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.serving import ContinuousServeEngine, Request, ServeEngine
from repro.training import TrainLoopConfig, init_train_state, make_train_step


def main():
    print("assigned architectures:", ", ".join(list_configs()))
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)

    # --- train ---
    loop = TrainLoopConfig(optimizer=AdamWConfig(lr=3e-3), warmup_steps=5,
                           total_steps=60)
    state = init_train_state(model, jax.random.PRNGKey(0), loop)
    ds = SyntheticLMData(cfg, seq_len=32, global_batch=8)
    step = jax.jit(make_train_step(model, loop))
    for i in range(30):
        state, metrics = step(state, ds.batch_at(i))
        if i % 10 == 0:
            print(f"step {i:3d} loss {float(metrics['loss']):.4f}")

    # --- checkpoint / restore ---
    with tempfile.TemporaryDirectory() as d:
        save(d, 30, state)
        assert latest_step(d) == 30
        state = restore(d, 30, state)
        print("checkpoint roundtrip ok")

    # --- serve (static batch; eos_id=-1 keeps the demo un-truncated) ---
    engine = ServeEngine(model, state["params"], max_len=64, eos_id=-1)
    prompts = np.arange(1, 9, dtype=np.int32).reshape(2, 4)
    out = engine.generate(prompts, max_new_tokens=8)
    print("generated:", out.tolist())

    # --- serve (continuous batching: slots, chunked prefill, scheduler) ---
    cont = ContinuousServeEngine(model, state["params"], n_slots=2,
                                 max_len=64, eos_id=-1)
    report = cont.run([Request(f"r{i}", prompts[i], 8) for i in range(2)])
    assert all(np.array_equal(report.output(f"r{i}"), out[i]) for i in range(2))
    print(f"continuous batching matched token-for-token "
          f"({report.generated_tokens} tokens, "
          f"{report.tok_per_s:.0f} tok/s)")


if __name__ == "__main__":
    main()
